"""Fused Pallas flash-decode kernel + speculative decoding + the
consolidated serving-program API (docs/serving.md §9).

Three layers of guarantees:

  - KERNEL: ``flash_decode`` (interpret=True executes the Pallas body on
    CPU) matches the pure-jnp oracle ``flash_decode_ref`` over ragged
    ``pos``, dead rows, OOB page-map rows and every GQA shape — and dead
    / no-valid-key rows come out EXACTLY zero, never NaN;
  - ENGINE: a ServingEngine running the flash kernel (dense AND paged)
    serves byte-identical token streams to the XLA-oracle engine on
    identical schedules (dense + moe), and speculative decoding emits
    the EXACT greedy stream of the non-speculative engine while keeping
    the trace discipline (one draft trace + prefill buckets + ONE verify
    bucket);
  - API: ``serving=ServingConfig(...)`` is the engine's only
    construction form (the flat kwargs and the five historical
    ``build_*_step`` factories finished their deprecation cycle and are
    gone — both removals pinned here), and invalid configs fail AT
    CONSTRUCTION with messages naming the offending values.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.kernels.flash_decode import flash_decode, flash_decode_ref
from repro.models import transformer as tf
from repro.serving import (PagingConfig, SamplingConfig, ServeRequest,
                           ServingConfig, ServingEngine,
                           SpeculativeConfig)
from repro.train.step import build_serve_programs

TINY_DENSE = ArchConfig(
    name="tiny-dense", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=61, head_dim=16,
    param_dtype="float32", activ_dtype="float32", tie_embeddings=True)

TINY_MOE = ArchConfig(
    name="tiny-moe", arch_type="moe", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=61, head_dim=16,
    param_dtype="float32", activ_dtype="float32", tie_embeddings=True,
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_ff_expert=32,
                  capacity_factor=4.0))


def _params(cfg, seed=0):
    return tf.init_params(jax.random.PRNGKey(seed), cfg)


def _mk_requests(cfg, rng, n, max_prompt=10, max_new=6):
    reqs = []
    for rid in range(n):
        p = int(rng.randint(1, max_prompt + 1))
        g = int(rng.randint(2, max_new + 1))
        reqs.append(ServeRequest(
            rid=rid, prompt=rng.randint(0, cfg.vocab_size, p).astype(
                np.int32), max_new=g))
    return reqs


def _tokens_by_rid(stats):
    return {c.rid: c.tokens.tolist() for c in stats.completions}


# ---------------------------------------------------------------------------
# kernel: ref vs Pallas interpret parity
# ---------------------------------------------------------------------------
def _mk_case(key, B, H, K, D, n_pages, ps, P, seed_pos=None):
    """Random pool + a page map with live pages up front and OOB (==
    n_pages) everywhere past each row's allocation — the engine's rmap
    contract."""
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kpool = jax.random.normal(ks[1], (B * 0 + n_pages, ps, K, D),
                              jnp.float32)
    vpool = jax.random.normal(ks[2], (n_pages, ps, K, D), jnp.float32)
    rng = np.random.RandomState(
        int(jax.random.randint(ks[3], (), 0, 2**31 - 1)))
    pos = rng.randint(0, P * ps, size=B).astype(np.int32) \
        if seed_pos is None else np.asarray(seed_pos, np.int32)
    pm = np.full((B, P), n_pages, np.int32)
    for b in range(B):
        used = int(pos[b]) // ps + 1
        pm[b, :used] = rng.choice(n_pages, size=used, replace=False)
    live = np.ones(B, np.int32)
    return q, kpool, vpool, jnp.asarray(pm), jnp.asarray(pos), \
        jnp.asarray(live)


CASES = [
    # B, H, K, D, n_pages, ps, P
    (4, 4, 2, 16, 16, 4, 4),      # GQA
    (2, 4, 4, 32, 8, 8, 2),       # MHA
    (3, 8, 1, 16, 32, 4, 8),      # MQA
    (1, 2, 2, 64, 4, 16, 2),      # single row, big pages
]


@pytest.mark.parametrize("B,H,K,D,NP,ps,P", CASES)
def test_flash_decode_matches_ref(B, H, K, D, NP, ps, P):
    case = _mk_case(jax.random.PRNGKey(B * 100 + H), B, H, K, D, NP, ps, P)
    out = flash_decode(*case, interpret=True)
    ref = flash_decode_ref(*case)
    assert out.shape == (B, H, D)
    assert jnp.abs(out - ref).max() < 2e-5


def test_flash_decode_ragged_pos_and_dead_rows():
    """Rows at every fill level incl. pos=0, plus dead rows: dead rows
    must come out EXACTLY zero (the engine discards them, but NaN would
    poison the out-projection of live rows in a fused batch)."""
    B, H, K, D, NP, ps, P = 5, 4, 2, 16, 12, 4, 3
    q, kp, vp, pm, pos, _ = _mk_case(
        jax.random.PRNGKey(0), B, H, K, D, NP, ps, P,
        seed_pos=[0, 3, 7, 11, 5])
    live = jnp.asarray([1, 1, 0, 1, 0], jnp.int32)
    out = flash_decode(q, kp, vp, pm, pos, live, interpret=True)
    ref = flash_decode_ref(q, kp, vp, pm, pos, live)
    assert jnp.abs(out - ref).max() < 2e-5
    assert bool((out[2] == 0.0).all()) and bool((out[4] == 0.0).all())
    assert bool(jnp.isfinite(out).all())


def test_flash_decode_oob_page_rows_are_skipped():
    """Pages past a row's allocation are marked OOB (== n_pages) in the
    map; flipping them to arbitrary VALID page ids holding garbage must
    not change the output, because pos masks those columns anyway —
    while flipping a page the row DOES read must."""
    B, H, K, D, NP, ps, P = 2, 4, 2, 16, 8, 4, 4
    q, kp, vp, pm, pos, live = _mk_case(
        jax.random.PRNGKey(5), B, H, K, D, NP, ps, P, seed_pos=[5, 2])
    base = flash_decode(q, kp, vp, pm, pos, live, interpret=True)
    # row 0 uses pages [0..1], rows beyond are OOB: point them anywhere
    pm_alias = pm.at[0, 3].set(0).at[1, 2].set(1)
    out = flash_decode(q, kp, vp, pm_alias, pos, live, interpret=True)
    assert jnp.abs(out - base).max() == 0.0
    pm_swap = pm.at[0, 0].set(pm[1, 0])      # a page row 0 DOES read
    out2 = flash_decode(q, kp, vp, pm_swap, pos, live, interpret=True)
    assert jnp.abs(out2 - base).max() > 1e-3


def test_flash_decode_identity_map_is_dense_attention():
    """With the identity page map the pool is just a dense (B, T) cache
    — the kernel must reproduce plain masked attention over it."""
    B, H, K, D, ps, nb = 3, 4, 2, 16, 4, 4
    T = ps * nb
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    ck = jax.random.normal(ks[1], (B, T, K, D), jnp.float32)
    cv = jax.random.normal(ks[2], (B, T, K, D), jnp.float32)
    pos = jnp.asarray([3, 9, 15], jnp.int32)
    live = jnp.ones(B, jnp.int32)
    idmap = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    out = flash_decode(q, ck.reshape(B * nb, ps, K, D),
                       cv.reshape(B * nb, ps, K, D), idmap, pos, live,
                       interpret=True)
    # plain grouped attention oracle over the dense cache
    G = H // K
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, ck.astype(jnp.float32))
    s = s / jnp.sqrt(D)
    mask = jnp.arange(T)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgt,btkd->bkgd", p,
                     cv.astype(jnp.float32)).reshape(B, H, D)
    assert jnp.abs(out - ref).max() < 2e-5


# ---------------------------------------------------------------------------
# engine: flash kernel serves bit-identical streams (dense + paged)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_engine_flash_dense_matches_oracle_bit_exact(cfg):
    params = _params(cfg)
    rng = np.random.RandomState(21)
    reqs = _mk_requests(cfg, rng, 12, max_prompt=12, max_new=6)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=32,
                                                         prompt_cap=8))
    flash = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=32, prompt_cap=8, decode_kernel="flash"))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    got = flash.run_closed_loop(reqs)
    assert _tokens_by_rid(got) == ref
    # the kernel's pos-bounded scan reads fewer KV tokens than the dense
    # rectangle — the counter the cost model charges must show it
    assert 0 < got.decode_kv_tokens < got.decode_rows_total * 32
    assert flash.trace_count == 1 + len(flash.buckets_seen)


@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_engine_flash_paged_matches_oracle_bit_exact(cfg):
    params = _params(cfg)
    rng = np.random.RandomState(22)
    reqs = _mk_requests(cfg, rng, 12, max_prompt=12, max_new=6)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=32,
                                                         prompt_cap=8))
    flash = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=32, prompt_cap=8, decode_kernel="flash",
        paging=PagingConfig(page_size=8)))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    assert _tokens_by_rid(flash.run_closed_loop(reqs)) == ref
    assert flash.trace_count == 1 + len(flash.buckets_seen)


def test_engine_flash_paged_prefix_reuse_still_exact():
    """Flash decode reads through the SHARED (frozen) prefix pages too —
    reuse + COW must stay bit-exact under the kernel."""
    from repro.core.simulation import generate_requests
    cfg = TINY_DENSE
    params = _params(cfg)
    reqs = generate_requests(
        14, rate_rps=200.0, vocab_size=cfg.vocab_size, prompt_rng=(4, 8),
        gen_short=(2, 4), gen_long=(4, 6), long_frac=0.3,
        shared_prefix=(2, 16, 0.8), seed=9)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=64))
    flash = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=64, decode_kernel="flash",
        paging=PagingConfig(page_size=8)))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    stats = flash.run_closed_loop(reqs)
    assert _tokens_by_rid(stats) == ref
    assert stats.prefix_hits > 0          # reuse actually fired


# ---------------------------------------------------------------------------
# speculative decoding: exact greedy stream, one verify bucket
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_speculative_emits_exact_greedy_stream(paged):
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(31)
    reqs = _mk_requests(cfg, rng, 10, max_prompt=10, max_new=8)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=64))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    # a DIFFERENT-SEED draft: disagrees with the target often, so the
    # accept rule is exercised on real rejections — output must not move
    spec = SpeculativeConfig(draft_params=_params(cfg, seed=3),
                             draft_cfg=cfg, k=3, window=16)
    eng = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=64, speculative=spec,
        paging=PagingConfig(page_size=16) if paged else None))
    stats = eng.run_closed_loop(reqs)
    assert _tokens_by_rid(stats) == ref
    assert stats.drafted > 0
    # trace discipline: one DRAFT trace + one per prefill bucket + the
    # single pinned verify bucket (vcap = pow2_bucket(k+1)); there is NO
    # plain decode trace in speculative mode
    assert eng.verify_buckets_seen == [(4, 4)]
    assert eng.trace_count == 1 + len(eng.buckets_seen) \
        + len(eng.verify_buckets_seen)


def test_speculative_perfect_draft_accepts_everything():
    """Draft == target: every draft token matches the target's argmax,
    so the accept rule must take all k + the bonus token every round."""
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(32)
    reqs = _mk_requests(cfg, rng, 8, max_prompt=8, max_new=8)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=64))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    spec = SpeculativeConfig(draft_params=params, draft_cfg=cfg, k=4,
                             window=32)
    eng = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=64, speculative=spec))
    stats = eng.run_closed_loop(reqs)
    assert _tokens_by_rid(stats) == ref
    assert stats.drafted > 0 and stats.accepted == stats.drafted
    # accepting k+1 tokens per round needs far fewer dispatches than
    # one-token-at-a-time decode — the speculative win the bench gates
    assert stats.decode_dispatches < base.decode_dispatches


def test_speculative_moe_and_cross_arch_draft():
    """A dense draft can speculate for a moe target (vocab superset);
    the stream stays the moe engine's exact greedy output."""
    cfg = TINY_MOE
    params = _params(cfg)
    rng = np.random.RandomState(33)
    reqs = _mk_requests(cfg, rng, 8, max_prompt=8, max_new=6)
    base = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=64))
    ref = _tokens_by_rid(base.run_closed_loop(reqs))
    spec = SpeculativeConfig(draft_params=_params(TINY_DENSE, seed=5),
                             draft_cfg=TINY_DENSE, k=2, window=16)
    eng = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=64, speculative=spec))
    assert _tokens_by_rid(eng.run_closed_loop(reqs)) == ref


# ---------------------------------------------------------------------------
# ServingConfig: grouped == flat, validation at construction
# ---------------------------------------------------------------------------
def test_serving_config_equals_flat_kwargs():
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(41)
    reqs = _mk_requests(cfg, rng, 8)
    flat = ServingEngine(params, cfg,
                         serving=ServingConfig.from_flat(max_batch=4,
                                                         max_seq=32,
                                                         prompt_cap=8,
                                                         temperature=0.7,
                                                         top_k=5,
                                                         sample_seed=3,
                                                         page_size=8))
    grouped = ServingEngine(params, cfg, serving=ServingConfig(
        max_batch=4, max_seq=32, prompt_cap=8,
        sampling=SamplingConfig(temperature=0.7, top_k=5, sample_seed=3),
        paging=PagingConfig(page_size=8)))
    assert _tokens_by_rid(flat.run_closed_loop(reqs)) \
        == _tokens_by_rid(grouped.run_closed_loop(reqs))


def test_mixing_serving_and_flat_kwargs_rejected():
    # the flat kwargs finished their deprecation cycle, so "mixing" is
    # no longer a ValueError at the disambiguation layer — the engine's
    # signature simply has no flat kwargs left to mix in
    cfg = TINY_DENSE
    params = _params(cfg)
    with pytest.raises(TypeError):
        ServingEngine(params, cfg,
                      serving=ServingConfig(max_batch=4, max_seq=32),
                      max_batch=4)


def test_page_size_divisibility_rejected_with_both_values_named():
    with pytest.raises(ValueError, match="max_seq=40.*page_size=16"):
        ServingConfig(max_batch=4, max_seq=40,
                      paging=PagingConfig(page_size=16))


def test_speculative_k_exceeding_prompt_cap_rejected():
    spec = SpeculativeConfig(draft_params={}, draft_cfg=TINY_DENSE,
                             k=8, window=16)
    with pytest.raises(ValueError, match="k=8.*prompt_cap=8"):
        ServingConfig(max_batch=4, max_seq=64, prompt_cap=8,
                      speculative=spec)


def test_speculative_requires_greedy():
    spec = SpeculativeConfig(draft_params={}, draft_cfg=TINY_DENSE,
                             k=2, window=8)
    with pytest.raises(ValueError, match="temperature=0"):
        ServingConfig(max_batch=4, max_seq=64,
                      sampling=SamplingConfig(temperature=0.5),
                      speculative=spec)


def test_more_construction_rejections():
    with pytest.raises(ValueError, match="decode_kernel='turbo'"):
        ServingConfig(max_batch=4, max_seq=32, decode_kernel="turbo")
    with pytest.raises(ValueError, match="window=2 must exceed k=2"):
        SpeculativeConfig(draft_params={}, draft_cfg=None, k=2, window=2)
    with pytest.raises(ValueError, match="n_pages requires page_size"):
        ServingConfig.from_flat(max_batch=4, max_seq=32, n_pages=8)


# ---------------------------------------------------------------------------
# the one-cycle deprecations are GONE: grouped construction is the API
# ---------------------------------------------------------------------------
def test_flat_constructions_removed():
    # the five build_*_step wrappers completed their deprecation cycle
    # (docs/serving.md §1 maps each to build_serve_programs)
    import repro.train.step as step_mod
    for old in ("build_prefill_step", "build_prefill_chunk_step",
                "build_paged_prefill_chunk_step", "build_paged_decode_step",
                "build_decode_step"):
        assert not hasattr(step_mod, old)
    # ...and so did the ServingEngine flat-kwarg constructor: the grouped
    # config is now required, flat kwargs are a TypeError
    cfg = TINY_DENSE
    with pytest.raises(TypeError):
        ServingEngine(_params(cfg), cfg, max_batch=4, max_seq=32)
    with pytest.raises(TypeError):
        ServingEngine(_params(cfg), cfg)
