"""Serving-path tests (docs/serving.md):

  - ragged prefill+decode through the slot KV cache must reproduce the
    full-forward NO-CACHE greedy oracle exactly, for ragged prompt
    lengths co-batched in one engine run;
  - engine fuzz: a seeded open-loop schedule completes every request,
    leaks no slots, and each slot's output is independent of its
    co-batched neighbors;
  - trace discipline: the jit trace count is bounded by the DISTINCT
    power-of-two (batch_cap, prompt_cap) buckets visited, not by the
    number of requests served.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.core.simulation import ServeCostModel, generate_requests
from repro.models import transformer as tf
from repro.serving import (ServeRequest, ServingConfig, ServingEngine,
                           pow2_bucket)

TINY_DENSE = ArchConfig(
    name="tiny-dense", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=61, head_dim=16,
    param_dtype="float32", activ_dtype="float32", tie_embeddings=True)

TINY_MOE = ArchConfig(
    name="tiny-moe", arch_type="moe", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=61, head_dim=16,
    param_dtype="float32", activ_dtype="float32", tie_embeddings=True,
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_ff_expert=32,
                  capacity_factor=4.0))


def _params(cfg, seed=0):
    return tf.init_params(jax.random.PRNGKey(seed), cfg)


def _mk_requests(cfg, rng, n, max_prompt=10, max_new=6):
    reqs = []
    for rid in range(n):
        p = int(rng.randint(1, max_prompt + 1))
        g = int(rng.randint(1, max_new + 1))
        reqs.append(ServeRequest(
            rid=rid, prompt=rng.randint(0, cfg.vocab_size, p).astype(
                np.int32), max_new=g))
    return reqs


def _full_forward_greedy(params, cfg, prompt, max_new):
    """The no-cache oracle: re-run the whole sequence through the
    TRAINING forward for every generated token."""
    toks = [int(t) for t in prompt]
    out = []
    for _ in range(max_new):
        logits, _ = tf.forward(params, cfg, jnp.asarray([toks]), remat=False)
        t = int(jnp.argmax(logits[0, -1]))
        out.append(t)
        toks.append(t)
    return out


# ---------------------------------------------------------------------------
# pow2 buckets
# ---------------------------------------------------------------------------
def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 4, 5, 9)] == [1, 2, 4, 4, 8, 16]
    assert pow2_bucket(3, lo=8) == 8
    assert pow2_bucket(100, hi=96) == 96          # clamped to max_seq


# ---------------------------------------------------------------------------
# ragged prefill == unpadded prefill
# ---------------------------------------------------------------------------
def test_ragged_prefill_matches_unpadded():
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(0)
    lens = np.array([5, 3, 8, 1], np.int32)
    toks = np.zeros((4, 8), np.int32)
    for b, L in enumerate(lens):
        toks[b, :L] = rng.randint(0, cfg.vocab_size, L)
    lg, _ = tf.prefill(params, cfg, jnp.asarray(toks), cache_len=8,
                       lengths=jnp.asarray(lens))
    for b, L in enumerate(lens):
        ref, _ = tf.prefill(params, cfg, jnp.asarray(toks[b:b + 1, :L]),
                            cache_len=int(L))
        np.testing.assert_allclose(np.asarray(lg[b]), np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_prefill_chunk_matches_unpadded(cfg):
    """Chunked ragged prefill (tf.prefill_chunk over slot cache rows) is
    bit-exact vs one unpadded single-shot prefill — logits AND cache."""
    params = _params(cfg)
    rng = np.random.RandomState(0)
    T, B, CH = 32, 3, 8
    lens = [13, 7, 21]
    prompts = [rng.randint(0, cfg.vocab_size, L).astype(np.int32)
               for L in lens]
    shape = (cfg.n_layers, B, T, cfg.n_kv_heads, cfg.head_dim)
    cache = {"layers": {"k": jnp.zeros(shape, jnp.float32),
                        "v": jnp.zeros(shape, jnp.float32)}}
    filled = [0] * B
    last_logits = [None] * B
    while any(filled[b] < lens[b] for b in range(B)):
        group = [b for b in range(B) if filled[b] < lens[b]]
        clens = [min(lens[b] - filled[b], CH) for b in group]
        toks = np.zeros((len(group), CH), np.int32)
        off = np.zeros(len(group), np.int32)
        cl = np.zeros(len(group), np.int32)
        for i, b in enumerate(group):
            toks[i, :clens[i]] = prompts[b][filled[b]:filled[b] + clens[i]]
            off[i], cl[i] = filled[b], clens[i]
        gi = jnp.asarray(group)
        rows = jax.tree.map(lambda c: c[:, gi], cache)
        lg, rows = tf.prefill_chunk(params, cfg, jnp.asarray(toks),
                                    jnp.asarray(off), jnp.asarray(cl),
                                    rows)
        cache = jax.tree.map(lambda c, r: c.at[:, gi].set(r), cache, rows)
        for i, b in enumerate(group):
            filled[b] += clens[i]
            if filled[b] == lens[b]:
                last_logits[b] = np.asarray(lg[i, 0])
    for b in range(B):
        ref_lg, ref_cache = tf.prefill(params, cfg,
                                       jnp.asarray(prompts[b][None, :]),
                                       cache_len=T)
        np.testing.assert_allclose(last_logits[b], np.asarray(ref_lg[0, 0]),
                                   rtol=1e-5, atol=1e-5)
        for name in ("k", "v"):
            np.testing.assert_allclose(
                np.asarray(cache["layers"][name][:, b, :lens[b]]),
                np.asarray(ref_cache["layers"][name][:, 0, :lens[b]]),
                rtol=1e-5, atol=1e-6)


def test_ragged_prefill_rejects_recurrent_archs():
    from repro.configs import get_config
    cfg = get_config("mamba2-780m").reduced()
    params = _params(cfg)
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(AssertionError, match="attention cache"):
        tf.prefill(params, cfg, toks, cache_len=8,
                   lengths=jnp.array([3, 5], jnp.int32))


# ---------------------------------------------------------------------------
# engine vs the full-forward no-cache oracle, ragged lengths in one batch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_engine_matches_full_forward_oracle(cfg):
    params = _params(cfg)
    rng = np.random.RandomState(3)
    reqs = _mk_requests(cfg, rng, n=5)
    # every prompt length distinct -> genuinely ragged co-batching
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=32))
    stats = engine.run_closed_loop(reqs)
    assert stats.n_requests == len(reqs)
    for c in stats.completions:
        req = reqs[c.rid]
        oracle = _full_forward_greedy(params, cfg, req.prompt, req.max_new)
        assert c.tokens.tolist() == oracle, (
            f"request {c.rid} (prompt_len={c.prompt_len}, "
            f"max_new={req.max_new}): engine {c.tokens.tolist()} != "
            f"no-cache oracle {oracle}")


# ---------------------------------------------------------------------------
# engine fuzz: seeded schedule -> no slot leaks, everyone completes,
# outputs independent of co-batched neighbors
# ---------------------------------------------------------------------------
def test_engine_fuzz_no_leaks_and_neighbor_independence():
    cfg = TINY_DENSE
    params = _params(cfg)
    reqs = generate_requests(
        30, rate_rps=400.0, vocab_size=cfg.vocab_size, prompt_rng=(1, 12),
        gen_short=(1, 6), gen_long=(8, 16), long_frac=0.25, seed=7)
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=32))
    stats = engine.run_simulated(reqs, ServeCostModel())

    # every request completes exactly once, with exactly max_new tokens
    by_rid = {r.rid: r for r in reqs}
    seen = sorted(c.rid for c in stats.completions)
    assert seen == sorted(by_rid), "lost or duplicated completions"
    for c in stats.completions:
        assert c.tokens.size == by_rid[c.rid].max_new
        assert c.finish >= by_rid[c.rid].arrival
        assert c.latency >= 2.0 * by_rid[c.rid].client_latency
    # no slot leaks: the engine drains to fully idle
    assert engine.n_live == 0 and engine.n_queued == 0
    assert all(s is None for s in engine._slots)
    assert not engine._live.any() and (engine._pos == 0).all()
    # per-slot outputs independent of co-batched neighbors: replaying any
    # request ALONE (same engine, so traces are shared) yields the same
    # tokens it got while sharing the cache with up to 3 others
    solo = {}
    for r in reqs[:8]:
        solo[r.rid] = engine.run_closed_loop(
            [ServeRequest(rid=r.rid, prompt=r.prompt,
                          max_new=r.max_new)]).completions[0]
    for c in stats.completions:
        if c.rid in solo:
            assert c.tokens.tolist() == solo[c.rid].tokens.tolist(), (
                f"request {c.rid}: co-batched output differs from solo run")


def test_engine_chunked_prefill_matches_oracle():
    """Prompts LONGER than the largest prefill bucket (prompt_cap) enter
    the slot cache chunk by chunk over several engine steps — outputs
    must still match the no-cache oracle, and the chunk buckets must
    stay capped at prompt_cap."""
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(9)
    lens = [30, 3, 17, 8, 25]
    reqs = [ServeRequest(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, L).astype(np.int32), max_new=4)
        for i, L in enumerate(lens)]
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=3,
                                                           max_seq=64,
                                                           prompt_cap=8))
    stats = engine.run_closed_loop(reqs)
    assert stats.n_requests == len(reqs)
    # chunking really happened: more chunk dispatches than admissions
    # would need in one shot, and no bucket wider than prompt_cap
    assert stats.prefill_chunks > len([L for L in lens if L > 8])
    assert all(c <= 8 for _, c in engine.buckets_seen)
    assert engine.trace_count == 1 + len(engine.buckets_seen)
    for c in stats.completions:
        req = reqs[c.rid]
        oracle = _full_forward_greedy(params, cfg, req.prompt, req.max_new)
        assert c.tokens.tolist() == oracle, (
            f"chunked request {c.rid} (prompt_len={c.prompt_len}): "
            f"{c.tokens.tolist()} != no-cache oracle {oracle}")


# ---------------------------------------------------------------------------
# sampling: temperature=0 IS the greedy oracle; seeded streams replay
# identically solo vs co-batched
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_temperature_zero_matches_greedy_oracle(cfg):
    params = _params(cfg)
    rng = np.random.RandomState(13)
    reqs = _mk_requests(cfg, rng, n=4)
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=32,
                                                           temperature=0.0,
                                                           sample_seed=123))
    stats = engine.run_closed_loop(reqs)
    for c in stats.completions:
        req = reqs[c.rid]
        oracle = _full_forward_greedy(params, cfg, req.prompt, req.max_new)
        assert c.tokens.tolist() == oracle


def test_top_k_one_matches_greedy_oracle():
    """top_k=1 leaves only the argmax in the categorical's support, so
    ANY temperature must reproduce the greedy stream — pins the top-k
    mask and the categorical draw to the same logits the argmax sees."""
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(17)
    reqs = _mk_requests(cfg, rng, n=4)
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=32,
                                                           temperature=1.7,
                                                           top_k=1,
                                                           sample_seed=5))
    stats = engine.run_closed_loop(reqs)
    for c in stats.completions:
        req = reqs[c.rid]
        oracle = _full_forward_greedy(params, cfg, req.prompt, req.max_new)
        assert c.tokens.tolist() == oracle


@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MOE],
                         ids=["dense", "moe"])
def test_top_k_tied_logits_keep_exactly_k(cfg):
    """Tied logits at the k-th value must NOT widen the support: the
    mask keeps ``jax.lax.top_k``'s own picks (stable descending sort,
    ties broken by LOWEST index), so a 3-way tie under top_k=2 samples
    only the two lowest tied indices — a ``lg < kth`` threshold would
    keep all three."""
    engine = ServingEngine(_params(cfg), cfg,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32,
                                                           temperature=1.0,
                                                           top_k=2,
                                                           sample_seed=7))
    logits = np.full((1, cfg.vocab_size), -5.0, np.float32)
    logits[0, [3, 10, 17]] = 2.0            # 3-way tie for the top value
    lg = jnp.asarray(logits)
    _, idx = jax.lax.top_k(lg, 2)
    assert idx[0].tolist() == [3, 10]       # the deterministic kept set
    drawn = {int(engine._sample(lg, jnp.asarray([0], jnp.int32),
                                jnp.asarray([g], jnp.int32))[0])
             for g in range(64)}
    assert drawn == {3, 10}, \
        f"support {sorted(drawn)} != top_k's picks [3, 10]"


def test_top_k_one_tied_argmax_matches_greedy():
    """With the argmax value repeated, top_k=1 must still equal the
    greedy path: argmax and top_k both resolve ties to the FIRST
    occurrence, so the sampled stream is pinned to it."""
    cfg = TINY_DENSE
    engine = ServingEngine(_params(cfg), cfg,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32,
                                                           temperature=2.3,
                                                           top_k=1,
                                                           sample_seed=9))
    logits = np.zeros((2, cfg.vocab_size), np.float32)
    logits[0, [5, 20]] = 3.0                # tied argmax, row 0
    logits[1, [0, 1, 60]] = 1.5             # 3-way tie incl. index 0
    lg = jnp.asarray(logits)
    for g in range(16):
        tok = engine._sample(lg, jnp.asarray([0, 1], jnp.int32),
                             jnp.asarray([g, g], jnp.int32))
        assert tok.tolist() == np.argmax(logits, axis=-1).tolist() == [5, 0]


def test_sampling_deterministic_solo_vs_cobatched():
    """A request's sampled stream depends only on (engine seed, rid,
    token index): co-batched and solo runs of the same engine config
    produce identical tokens, and a different seed produces different
    ones somewhere."""
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(21)
    reqs = _mk_requests(cfg, rng, n=6, max_prompt=8, max_new=8)

    def run(engine, rs):
        return {c.rid: c.tokens.tolist()
                for c in engine.run_closed_loop(rs).completions}

    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=32,
                                                           temperature=0.8,
                                                           top_k=7,
                                                           sample_seed=42))
    together = run(engine, reqs)
    solo = {}
    for r in reqs:
        solo.update(run(engine, [r]))       # same engine: traces shared
    assert together == solo
    other = ServingEngine(params, cfg,
                          serving=ServingConfig.from_flat(max_batch=4,
                                                          max_seq=32,
                                                          temperature=0.8,
                                                          top_k=7,
                                                          sample_seed=43))
    assert run(other, reqs) != together, "seed does not reach sampling"


def test_engine_reuses_freed_slots_without_scrubbing():
    """A long request keeps its slot while short neighbors cycle through
    the OTHER slots — successors must never see a predecessor's KV."""
    cfg = TINY_DENSE
    params = _params(cfg)
    rng = np.random.RandomState(11)
    long_req = ServeRequest(rid=0, prompt=rng.randint(0, 61, 6).astype(
        np.int32), max_new=20)
    shorts = [ServeRequest(rid=1 + i, prompt=rng.randint(0, 61, int(
        rng.randint(1, 10))).astype(np.int32), max_new=3)
        for i in range(6)]
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32))
    stats = engine.run_closed_loop([long_req] + shorts)
    assert stats.n_requests == 7
    for c in stats.completions:
        req = ([long_req] + shorts)[c.rid]
        oracle = _full_forward_greedy(params, cfg, req.prompt, req.max_new)
        assert c.tokens.tolist() == oracle, f"slot-reuse leak at rid {c.rid}"


# ---------------------------------------------------------------------------
# trace discipline: traces grow with capacity buckets, not request count
# ---------------------------------------------------------------------------
def test_trace_count_bounded_by_buckets():
    cfg = TINY_DENSE
    params = _params(cfg)
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=4,
                                                           max_seq=64,
                                                           prompt_bucket_min=8))
    rng = np.random.RandomState(5)

    def schedule(n, seed):
        return generate_requests(
            n, rate_rps=500.0, vocab_size=cfg.vocab_size,
            prompt_rng=(1, 30), gen_short=(1, 5), gen_long=(6, 10),
            long_frac=0.3, seed=seed)

    engine.run_simulated(schedule(20, seed=1), ServeCostModel())
    t1 = engine.trace_count
    buckets1 = set(engine.buckets_seen)
    # one decode trace + one per distinct (batch_cap, prompt_cap) bucket
    assert t1 == 1 + len(buckets1), (t1, sorted(buckets1))
    # prompt caps are pow2-bucketed within [prompt_bucket_min, max_seq],
    # batch caps within [1, max_batch] -> the bucket space is tiny
    for b, p in buckets1:
        assert b in (1, 2, 4) and p in (8, 16, 32, 64)

    # 3x more REQUESTS from the same distribution: traces grow only if a
    # genuinely new bucket shows up — never with request count
    engine.run_simulated(schedule(60, seed=2), ServeCostModel())
    t2 = engine.trace_count
    buckets2 = set(engine.buckets_seen)
    assert t2 == 1 + len(buckets2), (t2, sorted(buckets2))
    assert t2 - t1 == len(buckets2 - buckets1)

    # a longer prompt than ever seen forces EXACTLY one new trace
    new_len = 40                                  # pow2 bucket 64, unseen
    assert all(p < 64 for _, p in buckets2)
    engine.run_closed_loop([ServeRequest(
        rid=0, prompt=rng.randint(0, 61, new_len).astype(np.int32),
        max_new=2)])
    assert engine.trace_count == t2 + 1


# ---------------------------------------------------------------------------
# admission / configuration validation
# ---------------------------------------------------------------------------
def test_engine_validation():
    cfg = TINY_DENSE
    params = _params(cfg)
    engine = ServingEngine(params, cfg,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=16))
    with pytest.raises(ValueError, match="exceeds max_seq"):
        engine.submit(ServeRequest(rid=0, prompt=np.zeros(10, np.int32),
                                   max_new=7))
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit(ServeRequest(rid=1, prompt=np.zeros(0, np.int32),
                                   max_new=2))

    from repro.configs import get_config
    ssm_cfg = get_config("mamba2-780m").reduced()
    with pytest.raises(ValueError, match="attention-cached"):
        ServingEngine(_params(ssm_cfg), ssm_cfg,
                      serving=ServingConfig.from_flat(max_batch=2, max_seq=16))

    import dataclasses
    win_cfg = dataclasses.replace(cfg, sliding_window=8)
    with pytest.raises(ValueError, match="sliding_window"):
        ServingEngine(params, win_cfg,
                      serving=ServingConfig.from_flat(max_batch=2, max_seq=16))
    # a window that COVERS the whole slot cache is fine (linear == ring)
    ServingEngine(params, dataclasses.replace(cfg, sliding_window=16),
                  serving=ServingConfig.from_flat(max_batch=2, max_seq=16))
