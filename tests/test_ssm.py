"""Mamba2 SSD: chunked == sequential, prefill state == decode continuation,
numerical stability under strong decay."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMConfig
from repro.models.ssm import (init_ssm, init_ssm_state, ssd_chunked,
                              ssd_sequential, ssm_decode, ssm_forward,
                              ssm_prefill)

D = 32
CFG = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8)


def _core_inputs(key, B, S, nh, hd, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (64, 64), (7, 8)])
def test_chunked_equals_sequential(S, chunk):
    x, dt, A, Bm, Cm = _core_inputs(jax.random.PRNGKey(0), 2, S, 3, 16, 8)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    ys, hs = ssd_sequential(x, dt, A, Bm, Cm)
    assert jnp.abs(yc - ys).max() < 1e-4
    assert jnp.abs(hc - hs).max() < 1e-4


def test_strong_decay_stable():
    """A up to -16 (the init range) at long chunks must not overflow the
    masked exp (the NaN bug found in training: see ssm.py clamp)."""
    x, dt, A, Bm, Cm = _core_inputs(jax.random.PRNGKey(1), 1, 64, 2, 16, 8)
    A = jnp.asarray([-16.0, -8.0])
    yc, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    assert bool(jnp.isfinite(yc).all())
    g = jax.grad(lambda x: ssd_chunked(x, dt, A, Bm, Cm, chunk=32)[0].sum())(x)
    assert bool(jnp.isfinite(g).all())


def test_block_forward_matches_sequential_mode():
    p = init_ssm(jax.random.PRNGKey(0), D, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, D)) * 0.5
    y1 = ssm_forward(p, x, D, CFG, sequential=False)
    y2 = ssm_forward(p, x, D, CFG, sequential=True)
    assert jnp.abs(y1 - y2).max() < 1e-4


def test_prefill_then_decode_matches_full():
    p = init_ssm(jax.random.PRNGKey(0), D, CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 17, D)) * 0.5
    full = ssm_forward(p, x, D, CFG)
    y_pre, state = ssm_prefill(p, x[:, :16], D, CFG)
    assert jnp.abs(y_pre - full[:, :16]).max() < 1e-4
    y_t, state = ssm_decode(p, x[:, 16:17], state, D, CFG)
    assert jnp.abs(y_t[:, 0] - full[:, 16]).max() < 1e-4


def test_decode_chain_matches_full():
    p = init_ssm(jax.random.PRNGKey(0), D, CFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 12, D)) * 0.5
    full = ssm_forward(p, x, D, CFG)
    state = init_ssm_state(1, D, CFG)
    for t in range(12):
        y_t, state = ssm_decode(p, x[:, t:t + 1], state, D, CFG)
        assert jnp.abs(y_t[:, 0] - full[:, t]).max() < 1e-4, f"t={t}"


@settings(max_examples=15, deadline=None)
@given(S=st.integers(1, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
def test_chunked_sequential_property(S, chunk, seed):
    x, dt, A, Bm, Cm = _core_inputs(jax.random.PRNGKey(seed), 1, S, 2, 8, 8)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    ys, hs = ssd_sequential(x, dt, A, Bm, Cm)
    assert jnp.abs(yc - ys).max() < 1e-4
    assert jnp.abs(hc - hs).max() < 1e-4
