"""Simulator fidelity vs the paper's §3.5 observations."""
import numpy as np

from repro.core.simulation import GRID_NODE, SimulatedCluster
from repro.core import (JoinEvent, MasterEventLoop, MasterReducer,
                        UploadDataEvent)
from repro.core.scheduler import AdaptiveScheduler
from repro.optim import sgd


def _power_at(n_workers: int, T=4.0, iters=6) -> tuple:
    """Synthetic-compute sweep: returns (vectors/sec, mean latency)."""
    red = MasterReducer({"w": np.zeros(1)}, sgd(lr=0.0))
    cluster = SimulatedCluster(mode="synthetic", seed=1)
    loop = MasterEventLoop(reducer=red, cluster=cluster,
                           scheduler=AdaptiveScheduler(
                               T=T, prior_power=GRID_NODE.power_vps))
    loop.submit(UploadDataEvent(range(60_000)))
    for i in range(n_workers):
        w = f"w{i}"
        cluster.add_worker(w, GRID_NODE)
        loop.submit(JoinEvent(w, capacity=3000))
    logs = loop.run(iters)
    tail = logs[2:]
    return (float(np.mean([lg.power for lg in tail])),
            float(np.mean([lg.mean_latency for lg in tail])))


def test_power_scales_linearly_small_n():
    p1, _ = _power_at(1)
    p8, _ = _power_at(8)
    assert 6.0 < p8 / p1 <= 8.5, p8 / p1


def test_latency_jump_at_large_n():
    """Paper Fig. 4: latency explodes past ~64 nodes as messages queue at
    the single master."""
    _, l4 = _power_at(4)
    _, l96 = _power_at(96)
    assert l96 > 10 * l4
    assert l96 > 0.5          # the paper's ~1s regime


def test_scaling_efficiency_drops_past_64():
    p32, _ = _power_at(32)
    p96, _ = _power_at(96)
    per32 = p32 / 32
    per96 = p96 / 96
    assert per96 < 0.85 * per32     # sub-linear tail, as in Fig. 4


def test_worker_capacity_bounds_data():
    """Paper: '1 slave node trains on 3/60 of the full training set' —
    3000-vector cap."""
    red = MasterReducer({"w": np.zeros(1)}, sgd(lr=0.0))
    cluster = SimulatedCluster(mode="synthetic", seed=0)
    loop = MasterEventLoop(reducer=red, cluster=cluster,
                           scheduler=AdaptiveScheduler(T=4.0))
    loop.submit(UploadDataEvent(range(60_000)))
    cluster.add_worker("w0", GRID_NODE)
    loop.submit(JoinEvent("w0", capacity=3000))
    loop.run(1)
    assert loop.allocator.allocation_counts()["w0"] == 3000
    assert len(loop.allocator.unallocated) == 57_000


def test_unreliable_worker_detected():
    from repro.core.simulation import DeviceProfile
    flaky = DeviceProfile("flaky", 100.0, 0.01, 0.1, reliability=0.0)
    red = MasterReducer({"w": np.zeros(1)}, sgd(lr=0.0))
    cluster = SimulatedCluster(mode="synthetic", seed=0)
    loop = MasterEventLoop(reducer=red, cluster=cluster,
                           scheduler=AdaptiveScheduler(T=1.0))
    loop.submit(UploadDataEvent(range(100)))
    cluster.add_worker("w0", flaky)
    loop.submit(JoinEvent("w0", capacity=100))
    loop.iteration()          # worker dies mid-iteration -> LeaveEvent
    loop.iteration()          # event processed at boundary
    assert "w0" not in loop.registry
    loop.allocator.check_invariants()
