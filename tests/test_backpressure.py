"""Serving admission control (docs/robustness.md): the bounded queue,
the two shed policies, per-request admission deadlines, the
duplicate-rid guard, and the session-level accounting contract — every
request is ANSWERED (completed or explicitly shed), never silently
lost, and an admitted request always finishes."""
import numpy as np
import pytest

from repro.core.simulation import ServeCostModel, generate_requests
from repro.launch.train_serve import tiny_cfg
from repro.models import transformer as tf
from repro.serving import (ServeRequest, ServingConfig, ServingEngine,
                           SimulatedServeSession)

import jax

CFG = tiny_cfg()


def _params(seed=0):
    return tf.init_params(jax.random.PRNGKey(seed), CFG)


def _req(rid, plen=4, max_new=4, seed=None, **kw):
    rng = np.random.RandomState(rid if seed is None else seed)
    return ServeRequest(rid=rid, prompt=rng.randint(
        0, CFG.vocab_size, plen).astype(np.int32), max_new=max_new, **kw)


# ---------------------------------------------------------------------------
# duplicate rid: protocol error, not silent corruption
# ---------------------------------------------------------------------------
def test_duplicate_rid_rejected_while_queued():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32))
    engine.submit(_req(7))
    with pytest.raises(ValueError, match="duplicate rid"):
        engine.submit(_req(7))


def test_duplicate_rid_rejected_while_in_flight():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32))
    engine.submit(_req(7, max_new=6))
    engine.step()                              # rid 7 now holds a slot
    assert engine.n_queued == 0
    with pytest.raises(ValueError, match="duplicate rid"):
        engine.submit(_req(7))
    while engine.has_work:                     # after completion the rid
        engine.step()                          # is legal again
    assert engine.submit(_req(7))


def test_rid_reusable_across_runs():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32))
    engine.run_closed_loop([_req(0)])
    stats = engine.run_closed_loop([_req(0)])  # replay: same rid is fine
    assert stats.n_requests == 1


# ---------------------------------------------------------------------------
# bounded queue + shed policies
# ---------------------------------------------------------------------------
def test_reject_policy_sheds_newcomer():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           max_queue=2,
                                                           shed_policy="reject"))
    assert engine.submit(_req(0))
    assert engine.submit(_req(1))
    assert not engine.submit(_req(2), now=3.5)
    assert engine.n_queued == 2 and engine.queue_peak == 2
    assert [(s.rid, s.reason, s.t) for s in engine.shed_log] == \
        [(2, "queue_full", 3.5)]
    # the shed rid was never admitted, so it may retry later
    engine.step()                              # rid 0 -> slot, queue drains
    assert engine.submit(_req(2))


def test_drop_oldest_policy_displaces_stalest_wait():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           max_queue=2,
                                                           shed_policy="drop_oldest"))
    for rid in range(3):
        assert engine.submit(_req(rid), now=float(rid))
    assert [r.rid for r in engine._queue] == [1, 2]
    assert [(s.rid, s.reason) for s in engine.shed_log] == \
        [(0, "displaced")]
    assert engine.queue_peak == 2


def test_shed_policy_validated():
    with pytest.raises(ValueError, match="shed_policy"):
        ServingEngine(_params(), CFG,
                      serving=ServingConfig.from_flat(max_batch=1, max_seq=32,
                                                      max_queue=1,
                                                      shed_policy="explode"))
    with pytest.raises(ValueError, match="max_queue"):
        ServingEngine(_params(), CFG,
                      serving=ServingConfig.from_flat(max_batch=1, max_seq=32,
                                                      max_queue=0))


# ---------------------------------------------------------------------------
# admission deadlines: stale queued requests shed, in-flight never
# ---------------------------------------------------------------------------
def test_queued_request_sheds_past_deadline():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           admission_deadline=1.0))
    engine.submit(_req(0, max_new=8, arrival=0.0))
    engine.submit(_req(1, arrival=0.0))
    engine.step(now=0.5)                       # rid 0 admitted; 1 queued
    rep = engine.step(now=2.0)                 # rid 1 waited 2.0 > 1.0
    assert [(s.rid, s.reason) for s in rep.shed] == [(1, "deadline")]
    assert engine.n_queued == 0
    while engine.has_work:                     # rid 0 is IN FLIGHT: it
        rep = engine.step(now=99.0)            # finishes regardless
    done = [c.rid for c in rep.completed]
    assert done == [0]


def test_per_request_deadline_overrides_engine_default():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           admission_deadline=10.0))
    engine.submit(_req(0, max_new=8, arrival=0.0))
    engine.submit(_req(1, arrival=0.0, deadline=0.5))   # impatient client
    engine.submit(_req(2, arrival=0.0))                 # patient default
    engine.step(now=0.0)
    rep = engine.step(now=1.0)
    assert [(s.rid, s.reason) for s in rep.shed] == [(1, "deadline")]
    assert [r.rid for r in engine._queue] == [2]


def test_step_without_now_never_deadline_sheds():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           admission_deadline=0.001))
    engine.submit(_req(0))
    engine.submit(_req(1))
    while engine.has_work:                     # closed-loop: no clock, no
        engine.step()                          # deadline pressure
    assert engine.shed_log == []


# ---------------------------------------------------------------------------
# session accounting: completed + shed == submitted, bit-equal outputs
# ---------------------------------------------------------------------------
def test_session_overload_burst_sheds_are_accounted_and_bounded():
    reqs = generate_requests(
        40, rate_rps=30.0, vocab_size=CFG.vocab_size, prompt_rng=(4, 20),
        gen_short=(2, 6), gen_long=(8, 12), long_frac=0.3,
        burst=(0.2, 0.5, 8.0), seed=9)
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=64,
                                                           prompt_cap=16,
                                                           max_queue=3,
                                                           shed_policy="reject"))
    session = SimulatedServeSession(engine, ServeCostModel(), reqs)
    session.drain()
    stats = session.stats()
    assert stats.n_shed > 0, "burst never overflowed the queue"
    assert stats.queue_peak <= 3
    done = {c.rid for c in stats.completions}
    shed = {s.rid for s in stats.shed}
    assert done.isdisjoint(shed)
    assert done | shed == {r.rid for r in reqs}
    # survivors are uncorrupted: bit-equal to a solo replay
    by_rid = {r.rid: r for r in reqs}
    solo = ServingEngine(_params(), CFG,
                         serving=ServingConfig.from_flat(max_batch=2,
                                                         max_seq=64,
                                                         prompt_cap=16))
    for c in stats.completions[:5]:
        ref = solo.run_closed_loop([ServeRequest(
            rid=c.rid, prompt=by_rid[c.rid].prompt,
            max_new=by_rid[c.rid].max_new)]).completions[0]
        assert c.tokens.tolist() == ref.tokens.tolist()


def test_session_unbounded_queue_unchanged():
    """No max_queue, no deadlines: the historical contract holds — every
    request completes, zero sheds."""
    reqs = generate_requests(
        12, rate_rps=50.0, vocab_size=CFG.vocab_size, prompt_rng=(4, 16),
        gen_short=(2, 5), gen_long=(6, 8), long_frac=0.2, seed=3)
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=32))
    stats = engine.run_simulated(reqs, ServeCostModel())
    assert stats.n_shed == 0 and len(stats.completions) == len(reqs)
    assert stats.queue_peak >= 1


# ---------------------------------------------------------------------------
# shed timestamps: stamped with the submitting clock, monotone with
# the schedule — never t=0 for a request that arrived later
# ---------------------------------------------------------------------------
def test_shed_timestamps_monotone_on_simulated_clock():
    reqs = generate_requests(
        40, rate_rps=30.0, vocab_size=CFG.vocab_size, prompt_rng=(4, 20),
        gen_short=(2, 6), gen_long=(8, 12), long_frac=0.3,
        burst=(0.2, 0.5, 8.0), seed=9)
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=2,
                                                           max_seq=64,
                                                           prompt_cap=16,
                                                           max_queue=3,
                                                           shed_policy="reject"))
    session = SimulatedServeSession(engine, ServeCostModel(), reqs)
    session.drain()
    sheds = session.stats().shed
    assert len(sheds) > 1, "burst never overflowed the queue"
    by_rid = {r.rid: r for r in reqs}
    ts = [s.t for s in sheds]
    assert ts == sorted(ts), "shed timestamps regressed"
    for s in sheds:
        # a queue_full shed is stamped with the session clock at submit
        # time — never before the newcomer even arrived, and never the
        # t=0 the historical bug stamped every session shed with
        assert s.t >= by_rid[s.rid].arrival - 1e-9
    assert max(ts) > 0.0


def test_submit_without_now_stamps_request_arrival():
    engine = ServingEngine(_params(), CFG,
                           serving=ServingConfig.from_flat(max_batch=1,
                                                           max_seq=32,
                                                           max_queue=1,
                                                           shed_policy="reject"))
    assert engine.submit(_req(0))
    assert not engine.submit(_req(1, arrival=2.5))   # no now= given
    (shed,) = engine.shed_log
    assert (shed.rid, shed.reason, shed.t) == (1, "queue_full", 2.5)
