"""Partial gradient communication (paper §5.1): sparsifier correctness,
wire-byte accounting, error-feedback mass conservation."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.compression import (GradientCompressor, dense_bytes)


def _tree(key, shapes=((32,), (8, 16))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.3, 0.05])
    c = GradientCompressor("topk", frac=2 / 6)
    sent, res = c.roundtrip({"x": x}, None)
    nz = np.nonzero(np.asarray(sent["x"]))[0]
    assert set(nz.tolist()) == {1, 3}
    # error feedback: sent + residual == original
    assert jnp.allclose(sent["x"] + res["x"], x, atol=1e-6)


def test_randk_unscaled_payload():
    """randk ships the UNSCALED payload: with error feedback in the loop
    the classical 1/frac rescaling amplifies delivered mass by 1/frac
    per unit of input mass and diverges under SGD (regression for that
    bug — see core/compression.py module docstring)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,))
    c = GradientCompressor("randk", frac=0.25, seed=3)
    sent, res = c.roundtrip({"x": x}, None)
    kept = np.asarray(sent["x"])
    nz = kept != 0
    assert abs(nz.mean() - 0.25) < 0.05
    assert np.allclose(kept[nz], np.asarray(x)[nz], atol=1e-5)
    # the unsent mass is exactly the residual
    assert np.allclose(kept + np.asarray(res["x"]), np.asarray(x),
                       atol=1e-5)


def test_blocktopk_one_per_block():
    x = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    c = GradientCompressor("blocktopk", frac=1 / 64)
    sent, _ = c.roundtrip({"x": x}, None)
    kept = np.asarray(sent["x"]).reshape(-1, 64)
    assert ((kept != 0).sum(axis=1) == 1).all()


def test_wire_bytes_budget():
    tree = _tree(jax.random.PRNGKey(2), ((1000,), (50, 20)))
    c = GradientCompressor("topk", frac=0.01)
    assert c.wire_bytes(tree) == 8 * (10 + 10)
    assert dense_bytes(tree) == 4 * 2000
    assert c.wire_bytes(tree) < 0.05 * dense_bytes(tree)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100),
       method=st.sampled_from(["topk", "randk", "blocktopk"]),
       frac=st.sampled_from([0.01, 0.1, 0.5]))
def test_error_feedback_mass_conservation(seed, method, frac):
    """residual_t + sent_t == grad_t + residual_{t-1} for every method."""
    key = jax.random.PRNGKey(seed)
    tree = _tree(key)
    c = GradientCompressor(method, frac=frac, seed=seed)
    sent, res = c.roundtrip(tree, None)
    for k in tree:
        assert jnp.allclose(sent[k] + res[k], tree[k], atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), frac=st.sampled_from([0.05, 0.1, 0.25]))
def test_randk_mask_differs_across_steps(seed, frac):
    """The randk subset must be re-drawn every iteration: the step counter
    is folded into the PRNG key (the seed-PRNGKey-reuse bug regression)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    c = GradientCompressor("randk", frac=frac, seed=seed)
    zero = {"x": jnp.zeros_like(x)}
    masks = []
    for step in range(3):
        sent, _ = c.roundtrip({"x": x}, zero, step=step)
        masks.append(np.asarray(sent["x"]) != 0)
        # flat packed path draws the same per-step freshness
        msg, _ = c.compress_flat(x, None, step=step)
        flat_sel = np.zeros(512, bool)
        flat_sel[np.asarray(msg.indices).reshape(-1)] = True
        assert flat_sel.sum() == c.flat_k(512)
        masks.append(flat_sel)
    # consecutive dense masks differ, consecutive packed masks differ
    assert (masks[0] != masks[2]).any(), "dense randk mask frozen across steps"
    assert (masks[2] != masks[4]).any()
    assert (masks[1] != masks[3]).any(), "flat randk mask frozen across steps"
    assert (masks[3] != masks[5]).any()
    # same step is reproducible
    again, _ = c.roundtrip({"x": x}, zero, step=0)
    assert ((np.asarray(again["x"]) != 0) == masks[0]).all()


def test_pallas_blocktopk_matches_compressor():
    """kernels/topk_compress is the TPU path of method='blocktopk'."""
    from repro.kernels.topk_compress import block_topk
    x = jax.random.normal(jax.random.PRNGKey(5), (512,))
    c = GradientCompressor("blocktopk", frac=1 / 32)
    sent, _ = c.roundtrip({"x": x}, jax.tree.map(jnp.zeros_like, {"x": x}))
    kern = block_topk(x, block_w=32, interpret=True)
    assert jnp.allclose(sent["x"], kern, atol=1e-6)
