"""Research closures (paper §2.3/§6.4): JSON round-trip fidelity for every
arch config, both encodings, lineage, and cross-tool readability."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.all_configs import ASSIGNED_ARCHS
from repro.core.closure import (FORMAT, ResearchClosure, config_from_json,
                                config_to_json, decode_tree, encode_tree)
from repro.models import cnn


def test_param_roundtrip_b64():
    params = cnn.init_params(jax.random.PRNGKey(0))
    enc = encode_tree(params, "b64")
    dec = decode_tree(json.loads(json.dumps(enc)))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(dec)):
        assert np.array_equal(np.asarray(a), b)


def test_param_roundtrip_listing_humanreadable():
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    enc = encode_tree(params, "listing")
    assert enc["w"]["data"] == [[0.0, 1.0, 2.0], [3.0, 4.0, 5.0]]
    dec = decode_tree(enc)
    assert np.array_equal(dec["w"], np.asarray(params["w"]))


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_config_roundtrip_all_archs(name):
    cfg = get_config(name)
    assert config_from_json(
        json.loads(json.dumps(config_to_json(cfg)))) == cfg


def test_full_closure_roundtrip(tmp_path):
    cfg = get_config("mlitb-cnn")
    params = cnn.init_params(jax.random.PRNGKey(0))
    clo = ResearchClosure(
        arch="mlitb-cnn", config=cfg,
        algorithm={"optimizer": "adagrad", "lr": 0.01, "T": 4.0,
                   "reduce": "weighted-mean"},
        params=params, metrics=[{"step": 1, "loss": 2.3}], step=1)
    path = str(tmp_path / "closure.json")
    clo.save(path)
    # universally readable: plain json.load must work
    raw = json.load(open(path))
    assert raw["format"] == FORMAT
    back = ResearchClosure.load(path)
    assert back.arch == clo.arch and back.config == cfg
    assert back.algorithm["optimizer"] == "adagrad"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back.params)):
        assert np.array_equal(np.asarray(a), b)


def test_lineage():
    cfg = get_config("mlitb-cnn")
    params = {"w": jnp.ones((2,))}
    c1 = ResearchClosure("mlitb-cnn", cfg, {"optimizer": "sgd"}, params)
    c2 = c1.child({"w": jnp.zeros((2,))}, step=10)
    assert c2.parent == c1.digest
    assert c2.step == 10


def test_rejects_foreign_format():
    with pytest.raises(ValueError):
        ResearchClosure.from_json(json.dumps({"format": "not-a-closure"}))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=64),
       st.sampled_from(["b64", "listing"]))
def test_roundtrip_property(values, encoding):
    arr = np.asarray(values, np.float32)
    enc = encode_tree({"x": arr}, encoding)
    dec = decode_tree(json.loads(json.dumps(enc)))
    assert np.array_equal(dec["x"], arr)
