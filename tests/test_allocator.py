"""Pie-cutter allocator properties (paper §3.3 a/b) — hypothesis-driven."""
from hypothesis import given, settings, strategies as st

from repro.core.allocator import DataAllocator


def test_basic_balance():
    a = DataAllocator()
    a.add_worker("w0", capacity=100)
    a.add_worker("w1", capacity=100)
    a.add_data(range(50))
    a.check_invariants()
    counts = a.allocation_counts()
    assert abs(counts["w0"] - counts["w1"]) <= 1
    assert sum(counts.values()) == 50


def test_pie_cutter_carves_balanced_share():
    a = DataAllocator()
    a.add_worker("w0", capacity=1000)
    a.add_data(range(90))
    assert a.allocation_counts()["w0"] == 90
    a.add_worker("w1", capacity=1000)
    a.check_invariants()
    counts = a.allocation_counts()
    assert counts["w1"] >= 90 // 2 - 1     # got its pie slice
    assert sum(counts.values()) == 90      # nothing lost


def test_pie_cutter_prefers_cached_indices():
    a = DataAllocator()
    a.add_worker("w0", capacity=1000)
    a.add_data(range(40))
    a.add_worker("w1", capacity=1000)
    # w1 leaves; its share returns to w0 (which cached everything at upload)
    before = a.transfers
    a.remove_worker("w1")
    a.check_invariants()
    assert a.allocation_counts()["w0"] == 40
    assert a.transfers == before  # re-allocation hit w0's cache, no transfer


def test_capacity_respected():
    a = DataAllocator()
    a.add_worker("w0", capacity=10)
    a.add_data(range(25))
    a.check_invariants()
    assert a.allocation_counts()["w0"] == 10
    assert len(a.unallocated) == 15
    a.add_worker("w1", capacity=10)
    a.check_invariants()
    assert len(a.unallocated) == 5


def test_lost_worker_reallocation():
    a = DataAllocator()
    for i in range(4):
        a.add_worker(f"w{i}", capacity=100)
    a.add_data(range(100))
    orphans = a.remove_worker("w2")
    a.check_invariants()
    assert len(orphans) >= 100 // 4 - 1
    assert sum(a.allocation_counts().values()) == 100   # all re-homed


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.one_of(
        st.tuples(st.just("join"), st.integers(0, 7), st.integers(5, 60)),
        st.tuples(st.just("leave"), st.integers(0, 7), st.just(0)),
        st.tuples(st.just("data"), st.integers(1, 40), st.just(0)),
    ), min_size=1, max_size=25))
def test_invariants_under_arbitrary_event_sequences(events):
    """No event order may double-allocate, leak, or overflow capacity."""
    a = DataAllocator()
    next_idx = 0
    live = set()
    for kind, x, cap in events:
        if kind == "join" and f"w{x}" not in live:
            a.add_worker(f"w{x}", capacity=cap)
            live.add(f"w{x}")
        elif kind == "leave" and f"w{x}" in live:
            a.remove_worker(f"w{x}")
            live.discard(f"w{x}")
        elif kind == "data":
            a.add_data(range(next_idx, next_idx + x))
            next_idx += x
        a.check_invariants()


@settings(max_examples=30, deadline=None)
@given(n_data=st.integers(10, 200), n_workers=st.integers(1, 10))
def test_balance_property(n_data, n_workers):
    """With ample capacity, allocation is balanced within 1 after any
    join order (the pie-cutter's contract)."""
    a = DataAllocator()
    a.add_worker("w0", capacity=10_000)
    a.add_data(range(n_data))
    for i in range(1, n_workers):
        a.add_worker(f"w{i}", capacity=10_000)
    a.check_invariants()
    counts = list(a.allocation_counts().values())
    assert sum(counts) == n_data
    # pie-cutter targets floor(total/n); later joiners may sit one below
    assert max(counts) - min(counts) <= max(2, n_data // n_workers // 2), \
        counts
