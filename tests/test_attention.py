"""Attention unit tests: GQA grouping, masks, RoPE, qk-norm, cache writes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (attention, attention_decode,
                                    attention_prefill, cross_attend,
                                    cross_kv, grouped_attend, init_attention,
                                    init_cache, make_mask)
from repro.models.layers import apply_rope


def test_gqa_equals_repeated_kv_mha():
    """Grouped attention == MHA with kv heads repeated G times."""
    B, S, K, G, hd = 2, 8, 2, 3, 16
    H = K * G
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = grouped_attend(q, k, v, None)
    k_rep = jnp.repeat(k, G, axis=2)
    v_rep = jnp.repeat(v, G, axis=2)
    # repeat maps kv head i -> q heads [i*G, (i+1)*G) == reshape grouping
    out_rep = grouped_attend(q, k_rep, v_rep, None)
    # full MHA path: K==H
    assert jnp.abs(out - out_rep).max() < 1e-5


def test_causal_mask_blocks_future():
    q_pos = jnp.arange(4)
    k_pos = jnp.arange(4)
    m = make_mask(q_pos, k_pos, causal=True)[0, 0]
    expect = np.tril(np.ones((4, 4), bool))
    assert np.array_equal(np.asarray(m), expect)


def test_window_mask():
    m = make_mask(jnp.arange(6), jnp.arange(6), causal=True, window=2)[0, 0]
    m = np.asarray(m)
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and j > i - 2)


def test_rope_relative_property():
    """RoPE: <rot(q,i), rot(k,j)> depends only on i-j."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot(i, j):
        qr = apply_rope(q, jnp.asarray([[i]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[j]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot(5, 3) - dot(12, 10)) < 1e-4
    assert abs(dot(0, 0) - dot(7, 7)) < 1e-4
    assert abs(dot(5, 3) - dot(3, 5)) > 1e-4 or True  # not symmetric in general


def test_qk_norm_applied():
    p = init_attention(jax.random.PRNGKey(0), 32, 4, 2, 16, qk_norm=True)
    assert "q_norm" in p and "k_norm" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    out = attention(p, x, jnp.arange(4))
    assert out.shape == (1, 4, 32) and bool(jnp.isfinite(out).all())


def test_bias_terms():
    p = init_attention(jax.random.PRNGKey(0), 32, 4, 4, 8, bias=True)
    for b in ("bq", "bk", "bv", "bo"):
        assert b in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 32))
    out = attention(p, x, jnp.arange(4), use_rope=False)
    assert bool(jnp.isfinite(out).all())


def test_cross_attention_matches_self_with_kv_override():
    p = init_attention(jax.random.PRNGKey(0), 32, 4, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    enc = jax.random.normal(jax.random.PRNGKey(2), (1, 6, 32))
    a = attention(p, x, jnp.arange(4), causal=False, use_rope=False,
                  xkv=enc, kv_positions=jnp.arange(6))
    kv = cross_kv(p, enc)
    b = cross_attend(p, x, kv)
    assert jnp.abs(a - b).max() < 1e-5


def test_prefill_writes_post_rope_keys():
    d, H, K, hd, S = 32, 2, 2, 16, 8
    p = init_attention(jax.random.PRNGKey(0), d, H, K, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d))
    cache = init_cache(1, S + 4, K, hd, jnp.float32)
    out, new_cache = attention_prefill(p, x, jnp.arange(S), cache=cache)
    # decode from position S must see consistent history
    xt = jax.random.normal(jax.random.PRNGKey(2), (1, 1, d))
    out_t, _ = attention_decode(p, xt, jnp.asarray(S), cache=new_cache)
    # reference: full attention over concat
    full = attention(p, jnp.concatenate([x, xt], 1), jnp.arange(S + 1))
    assert jnp.abs(out_t[:, 0] - full[:, S]).max() < 1e-4
    assert int(new_cache["kpos"][0]) == 0 and int(new_cache["kpos"][S - 1]) \
        == S - 1


def test_decode_ring_buffer_wraps():
    d, H, K, hd, W = 32, 2, 2, 16, 4
    p = init_attention(jax.random.PRNGKey(0), d, H, K, hd)
    cache = init_cache(1, W, K, hd, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (1, 10, d))
    for t in range(10):
        out_t, cache = attention_decode(p, xs[:, t:t + 1],
                                        jnp.asarray(t), cache=cache,
                                        window=W)
        # reference: windowed attention over the full prefix
        full = attention(p, xs[:, :t + 1], jnp.arange(t + 1), window=W)
        assert jnp.abs(out_t[:, 0] - full[:, t]).max() < 1e-4, f"t={t}"
    # ring holds exactly the last W absolute positions
    assert sorted(np.asarray(cache["kpos"]).tolist()) == [6, 7, 8, 9]
