"""Master event loop integration (paper §3.3): training under churn."""
import jax
import numpy as np
import pytest

from repro.core import (JoinEvent, LeaveEvent, MasterEventLoop,
                        MasterReducer, UploadDataEvent)
from repro.core.scheduler import AdaptiveScheduler
from repro.core.simulation import (GRID_NODE, LAPTOP, PHONE, NetworkModel,
                                   SimulatedCluster, WORKSTATION,
                                   make_cnn_problem)
from repro.data.datasets import synthetic_mnist
from repro.optim import adagrad


def _make_loop(n_workers=4, n_data=1200, profile=GRID_NODE, T=1.0,
               network=NetworkModel(), seed=0):
    init_p, grad_fn, eval_fn = make_cnn_problem()
    X, y = synthetic_mnist(n_data, seed=seed)
    params = init_p(jax.random.PRNGKey(seed))
    red = MasterReducer(params, adagrad(lr=0.02))
    cluster = SimulatedCluster(grad_fn=grad_fn, data=(X, y), mode="real",
                               network=network, seed=seed)
    loop = MasterEventLoop(reducer=red, cluster=cluster,
                           scheduler=AdaptiveScheduler(T=T, prior_power=113))
    loop.submit(UploadDataEvent(range(n_data)))
    for i in range(n_workers):
        w = f"w{i}"
        cluster.add_worker(w, profile)
        loop.submit(JoinEvent(w, capacity=3000))
    return loop, cluster, eval_fn, (X, y)


def test_loss_decreases():
    loop, _, eval_fn, _ = _make_loop()
    logs = loop.run(8)
    assert logs[-1].loss < logs[0].loss
    assert logs[-1].n_workers == 4


def test_elastic_join_leave_mid_training():
    loop, cluster, _, _ = _make_loop(n_workers=3)
    loop.run(3)
    loop.submit(LeaveEvent("w1"))
    logs = loop.run(2)
    assert logs[-1].n_workers == 2
    loop.allocator.check_invariants()
    cluster.add_worker("w9", GRID_NODE)
    loop.submit(JoinEvent("w9", capacity=3000))
    logs = loop.run(3)
    assert logs[-1].n_workers == 3
    loop.allocator.check_invariants()
    assert np.isfinite(logs[-1].loss)


def test_all_workers_leave_then_rejoin():
    loop, cluster, _, _ = _make_loop(n_workers=2)
    loop.run(2)
    loop.submit(LeaveEvent("w0"))
    loop.submit(LeaveEvent("w1"))
    logs = loop.run(1)
    assert logs[-1].n_workers == 0      # loop survives an empty network
    cluster.add_worker("w2", GRID_NODE)
    loop.submit(JoinEvent("w2", capacity=3000))
    logs = loop.run(2)
    assert logs[-1].n_workers == 1
    assert np.isfinite(logs[-1].loss)


def test_heterogeneous_devices_contribute_proportionally():
    init_p, grad_fn, _ = make_cnn_problem()
    X, y = synthetic_mnist(3000, seed=1)
    params = init_p(jax.random.PRNGKey(0))
    red = MasterReducer(params, adagrad(lr=0.02))
    cluster = SimulatedCluster(grad_fn=grad_fn, data=(X, y), mode="real")
    loop = MasterEventLoop(reducer=red, cluster=cluster,
                           scheduler=AdaptiveScheduler(T=1.0))
    loop.submit(UploadDataEvent(range(3000)))
    for w, prof in [("fast", WORKSTATION), ("mid", LAPTOP),
                    ("slow", PHONE)]:
        cluster.add_worker(w, prof)
        loop.submit(JoinEvent(w, capacity=1500))
    loop.run(6)
    s = loop.scheduler.stats
    # after EWMA settles, measured power ordering matches the profiles
    assert s["fast"].power > s["mid"].power > s["slow"].power
    # and the time-budgeted map step means NOBODY is idle-blocked: every
    # worker processed vectors every iteration it was live
    assert all(st.total_vectors > 0 for st in s.values())


def test_empty_fleet_iterations_advance_step():
    """Regression: the empty-fleet early return used to advance the
    clock but not the step counter, so consecutive empty iterations
    emitted duplicate step numbers in the history."""
    loop, cluster, _, _ = _make_loop(n_workers=0)
    logs = loop.run(3)                      # nobody ever joined
    assert [lg.step for lg in logs] == [1, 2, 3]
    assert all(lg.n_workers == 0 for lg in logs)
    assert loop.clock == pytest.approx(3 * loop.scheduler.T)
    # a worker joining afterwards continues the monotone numbering
    cluster.add_worker("w0", GRID_NODE)
    loop.submit(JoinEvent("w0", capacity=3000))
    log = loop.iteration()
    assert log.step == 4
    assert [lg.step for lg in loop.history] == [1, 2, 3, 4]


def test_convergence_reaches_low_test_error():
    loop, _, eval_fn, _ = _make_loop(n_workers=4, n_data=4000)
    loop.run(10)
    Xt, yt = synthetic_mnist(400, seed=77)
    err = eval_fn(loop.reducer.params, Xt, yt)
    assert err < 0.15, f"test error {err}"
